"""Network-transparent nodes: the broker/node layer (paper §2.1, CAF's
``middleman``; "Revisiting Actor Programming in C++" describes the
original).

A :class:`NodeRuntime` wraps one :class:`~repro.core.ActorSystem` with a
socket transport and a per-node **broker actor**. Remote actors are held
through :class:`RemoteActorRef` — an :class:`~repro.core.ActorRef`
subclass, so ``send``/``request``/``ask``/``monitor``/``link`` (and every
consumer built on them: pools, schedulers, pipelines, graphs) work
unchanged on actors living in another process. That is the paper's
network-transparency claim made concrete: local and remote actors share
one handle type.

Payloads cross the wire via :mod:`repro.net.wire` — pickle with
:class:`~repro.core.memref.DeviceRef` leaves auto-spilled at the boundary
(optionally int8-compressed) and unspilled onto a receiver-chosen device.

Supervision crosses nodes: monitoring a remote actor registers a relay on
its node that forwards the :class:`~repro.core.errors.DownMessage` home;
links are two one-way halves (``ActorSystem._link_half``), one per node.
A heartbeat loop (plus immediate socket-EOF detection) declares a peer
dead, which fails every pending request future to that peer and delivers
``DownMessage``/``ExitMessage`` to local monitors/links of its actors —
so ``repro.dist.fault``-style supervision and the
:class:`~repro.core.scheduler.ChunkScheduler`'s exactly-once re-issue
work across process boundaries with no special cases.
"""
from __future__ import annotations

import itertools
import os
import pickle
import socket
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Tuple

from concurrent.futures import TimeoutError as FuturesTimeout

from repro.core.actor import (Actor, ActorRef, ActorSystem, Message,
                              _safe_set_exception, _safe_set_result)
from repro.analysis.runtime import make_lock, make_rlock
from repro.core.errors import ActorError, ActorFailed, DownMessage, ExitMessage

from . import wire

__all__ = ["NodeRuntime", "RemoteActorRef", "NodeDown", "PayloadError"]

#: distinguishes "caller passed no timeout" from an explicit ``None``
#: (= wait forever) in the node-level RPCs (peer_stats, remote_actor,
#: spawn_remote) — mirrors ``ActorRef.ask``
_UNSET = object()


class NodeDown(ActorFailed):
    """A peer node died (socket EOF, heartbeat timeout, or graceful bye);
    raised from pending request futures and carried as the ``reason`` of
    the DownMessages/ExitMessages delivered to its actors' local
    monitors/links."""


class PayloadError(ActorError):
    """A payload blob could not be decoded on the receiving node (e.g. a
    ``spawn_remote`` behavior defined in the driver's ``__main__``, which
    the worker cannot import). Fails only the carrying request — the
    target actor is still alive and the connection stays up, so this is
    deliberately *not* an :class:`ActorFailed` (which would mark the
    remote actor dead on the requesting side)."""


class RemoteActorRef(ActorRef):
    """Handle to an actor living on another node.

    ``actor_id`` is the cluster-unique string ``"<peer>/<id>"`` (pools and
    schedulers key their routing tables by it); ``remote_id`` is the id —
    or published name — in the owning node's namespace. Everything else is
    the plain :class:`ActorRef` surface: ``ask`` inherits the system
    default timeout, ``__mul__`` still builds pipelines, and
    ``system.monitor``/``link`` dispatch here via ``is_remote``.
    """

    __slots__ = ("node", "peer", "remote_id")

    #: duck-typed dispatch flag checked by ActorSystem.monitor/link
    is_remote = True

    def __init__(self, node: "NodeRuntime", peer: str, remote_id):
        super().__init__(f"{peer}/{remote_id}", node.system)
        self.node = node
        self.peer = peer
        self.remote_id = remote_id

    # -- messaging ------------------------------------------------------
    def send(self, *payload: Any, sender: Optional[ActorRef] = None) -> None:
        self.node._send_to(self.peer, self.remote_id, payload)

    def request(self, *payload: Any) -> Future:
        return self.node._request_to(self.peer, self.remote_id, payload)

    # -- supervision ------------------------------------------------------
    def monitor(self, watcher: ActorRef) -> None:
        self.node._monitor_remote(self, watcher)

    def link(self, other: ActorRef) -> None:
        self.node._link_remote(self, other)

    def exit(self, reason: Any = None) -> None:
        self.node._exit_remote(self, reason)

    def is_alive(self) -> bool:
        return self.node._remote_alive(self.peer, self.remote_id)

    def __repr__(self):
        return f"RemoteActorRef#{self.peer}/{self.remote_id}"


class _Relay(Actor):
    """Exit-trapping forwarder: turns a locally delivered DownMessage /
    ExitMessage into a wire frame (or any side effect ``fn`` encodes)."""

    def __init__(self, fn: Callable[[Any], None]):
        super().__init__()
        self.trap_exit = True
        self._fn = fn

    def receive(self, msg):
        self._fn(msg)


class _Broker(Actor):
    """The per-node broker: every inbound frame (except heartbeats, which
    the reader threads answer inline for liveness) funnels through this
    actor's mailbox, so cross-node delivery shares the local runtime's
    ordering and isolation guarantees."""

    def __init__(self, node: "NodeRuntime"):
        super().__init__()
        self.trap_exit = True
        self._node = node

    def receive(self, peer: str, frame: tuple):
        self._node._handle(peer, frame)


#: sentinel for _send_reply: the reply answers a node-level rpc, not an
#: actor request — there is no target actor whose liveness to report
_RPC_TARGET = object()


class _Conn:
    __slots__ = ("peer", "sock", "alive", "last_rx", "wlock", "reader")

    def __init__(self, peer: str, sock: socket.socket):
        self.peer = peer
        self.sock = sock
        self.alive = True
        self.last_rx = time.monotonic()
        self.wlock = make_lock("ConnWrite")
        self.reader: Optional[threading.Thread] = None


def _safe_reason(reason: Any) -> Any:
    """Failure reasons travel inside control frames; an unpicklable one is
    downgraded to an ActorFailed carrying its repr rather than poisoning
    the frame."""
    try:
        pickle.dumps(reason)
        return reason
    except Exception:
        return ActorFailed(repr(reason))


class NodeRuntime:
    """One process's membership in the cluster (see module doc).

    Parameters
    ----------
    system : the local actor system this node fronts.
    name : cluster-unique node name (default: pid-derived).
    listen : optional ``(host, port)`` to accept peers on (port 0 picks a
        free port; see :attr:`address`).
    compress : int8-compress float refs at the wire boundary
        (:func:`repro.dist.collectives.quantize_ref` wire format).
        ``True``/``False`` force the choice; ``"auto"`` delegates it per
        payload to the process-wide placement service's wire-cost model
        (:meth:`repro.core.placement.PlacementService.choose_compress`),
        which compresses only when the estimated bytes saved amortize the
        quantization pass on this hop.
    unspill_device : where inbound refs land (``Device`` wrapper, bare
        ``jax.Device``, or None for the process default) — the paper's
        "receiver chooses" policy.
    rpc_timeout : default timeout for the node-level RPCs (``peer_stats``,
        ``remote_actor``, ``spawn_remote``); unset inherits the wrapped
        system's ``default_ask_timeout``, so cluster-wide latency policy is
        configured in one place instead of per-call constants. An explicit
        ``None`` waits forever.
    """

    def __init__(self, system: ActorSystem, name: Optional[str] = None,
                 listen: Optional[Tuple[str, int]] = None, *,
                 compress: Any = False, unspill_device=None,
                 heartbeat_interval: float = 1.0,
                 heartbeat_timeout: float = 5.0,
                 rpc_timeout: Any = _UNSET):
        self.system = system
        self.name = name or f"node-{os.getpid():x}"
        self.compress = compress
        self.unspill_device = unspill_device
        self.heartbeat_interval = heartbeat_interval
        self.heartbeat_timeout = heartbeat_timeout
        self.rpc_timeout = (getattr(system, "default_ask_timeout", 120.0)
                            if rpc_timeout is _UNSET else rpc_timeout)
        self._lock = make_rlock("NodeRuntime")
        self._cv = threading.Condition(self._lock)
        self._conns: Dict[str, _Conn] = {}
        self._pending: Dict[int, tuple] = {}   # req_id -> (peer, rid, Future)
        self._req_ids = itertools.count(1)
        self._published: Dict[str, ActorRef] = {}
        self._watchers: Dict[tuple, List[ActorRef]] = {}   # (peer,rid) -> refs
        self._link_locals: Dict[tuple, List[ActorRef]] = {}
        self._monitored_out: set = set()   # (peer, rid) monitor frames sent
        self._linked_out: set = set()
        self._relays: Dict[tuple, ActorRef] = {}  # serving-side forwarders
        self._dead_remote: set = set()
        self._dead_peers: set = set()
        self._closed = False
        #: set by shutdown(); sleep-free loops (heartbeat) wait on it so a
        #: node leaves the cluster promptly instead of lingering up to a
        #: full interval in time.sleep (mesh scale-in inherits that latency)
        self._closed_evt = threading.Event()
        #: extra peer_stats sections: name -> zero-arg callable merged into
        #: the "stats" rpc reply (e.g. the serve mesh's replica load report)
        self._stats_providers: Dict[str, Callable[[], Any]] = {}
        self.stats = {"frames_in": 0, "frames_out": 0, "frames_bad": 0,
                      "peers_lost": 0, "errors_swallowed": 0}
        #: last N exceptions a service loop chose to survive — surfaced
        #: through the "stats" rpc so swallowed faults stay observable
        self._swallowed: deque = deque(maxlen=32)
        self._broker = system.spawn(_Broker(self))
        self._listener: Optional[socket.socket] = None
        if listen is not None:
            self._listener = socket.create_server(listen)
            self._accept_thread = threading.Thread(
                target=self._accept_loop, name=f"{self.name}-accept",
                daemon=True)
            self._accept_thread.start()
        self._hb_thread = threading.Thread(
            target=self._heartbeat_loop, name=f"{self.name}-heartbeat",
            daemon=True)
        self._hb_thread.start()

    # -- cluster surface ---------------------------------------------------
    @property
    def address(self) -> Optional[Tuple[str, int]]:
        """The ``(host, port)`` peers connect to (None when not listening)."""
        if self._listener is None:
            return None
        return self._listener.getsockname()[:2]

    def connect(self, addr: Tuple[str, int], timeout: float = 30.0) -> str:
        """Dial a listening node; returns the peer's name after the
        hello handshake."""
        sock = socket.create_connection(tuple(addr), timeout=timeout)
        sock.settimeout(timeout)
        wire.write_frame(sock, wire.encode_frame(("hello", self.name)))
        data = wire.read_frame(sock)
        if data is None:
            raise ConnectionError(f"peer at {addr} closed during handshake")
        frame = wire.decode_frame(data)
        if frame[0] != "hello":
            raise ConnectionError(f"bad handshake frame {frame[0]!r}")
        peer = frame[1]
        sock.settimeout(None)
        self._register_conn(peer, sock)
        return peer

    def peers(self) -> List[str]:
        with self._lock:
            return [p for p, c in self._conns.items() if c.alive]

    def wait_for_peer(self, name: str, timeout: float = 30.0) -> bool:
        """Block until ``name`` connects (True) or ``timeout`` expires."""
        with self._cv:
            return self._cv.wait_for(
                lambda: name in self._conns and self._conns[name].alive,
                timeout=timeout)

    def join(self, timeout: Optional[float] = None) -> bool:
        """Block until every connection has closed (a worker node's main
        loop: serve until the driver goes away)."""
        with self._cv:
            return self._cv.wait_for(
                lambda: self._closed
                or not any(c.alive for c in self._conns.values()),
                timeout=timeout)

    # -- registry ------------------------------------------------------
    def publish(self, name: str, ref: ActorRef) -> ActorRef:
        """Expose ``ref`` to remote lookups under ``name`` (node-local
        namespace)."""
        with self._lock:
            self._published[name] = ref
        return ref

    def _rpc_result(self, peer: str, fut: Future, timeout: Any,
                    what: str) -> Any:
        """Await a node-level rpc reply with the configured timeout. On
        expiry the raised TimeoutError names the peer and its last-rx age
        — a wedged-but-talking peer (recent rx) is distinguishable from a
        silently dead one (stale rx) from the exception alone."""
        if timeout is _UNSET:
            timeout = self.rpc_timeout
        try:
            return fut.result(timeout)
        except FuturesTimeout:
            if fut.done():
                raise  # the rpc itself returned a TimeoutError result
            with self._lock:
                conn = self._conns.get(peer)
            if conn is None:
                age = "never connected"
            else:
                age = (f"last rx {time.monotonic() - conn.last_rx:.1f}s ago, "
                       f"conn {'alive' if conn.alive else 'dead'}")
            raise FuturesTimeout(
                f"{what} to node {peer!r} timed out after {timeout}s "
                f"({age})") from None

    def remote_actor(self, peer: str, name: str,
                     timeout: Any = _UNSET) -> RemoteActorRef:
        """Look up an actor ``peer`` published under ``name``."""
        rid = self._rpc_result(peer, self._rpc(peer, "lookup", (name,)),
                               timeout, f"remote_actor({name!r})")
        return RemoteActorRef(self, peer, rid)

    def spawn_remote(self, peer: str, behavior, *args, publish=None,
                     spawn_kwargs: Optional[dict] = None,
                     timeout: Any = _UNSET) -> RemoteActorRef:
        """Spawn ``behavior`` (a picklable callable / Actor subclass /
        KernelDecl) inside ``peer``'s actor system; optionally publish it
        there under ``publish``. ``spawn_kwargs`` forwards keyword
        arguments to the remote ``spawn`` (e.g. ``emit="ref"`` for a
        kernel declaration placed cross-node by the graph builder).
        Returns the network-transparent handle."""
        rid = self._rpc_result(peer,
                               self._rpc(peer, "spawn",
                                         (behavior, args, publish,
                                          spawn_kwargs or {})),
                               timeout, "spawn_remote")
        return RemoteActorRef(self, peer, rid)

    def peer_stats(self, peer: str, timeout: Any = _UNSET) -> dict:
        """The peer process's ``memory_stats()`` snapshot (plus any
        sections the peer registered via :meth:`add_stats_provider`, e.g.
        the serve mesh's per-replica load report) — how the two-process
        tests assert one spill/unspill pair per wire hop on *both* sides,
        and how a mesh router reads a worker node's load."""
        return self._rpc_result(peer, self._rpc(peer, "stats", ()),
                                timeout, "peer_stats")

    def add_stats_provider(self, name: str,
                           fn: Callable[[], Any]) -> None:
        """Merge ``fn()`` into this node's ``peer_stats`` reply under
        ``name``. A provider that raises contributes its error string
        instead of failing the whole stats rpc."""
        with self._lock:
            self._stats_providers[name] = fn

    def _note_error(self, where: str, exc: BaseException) -> None:
        """Record an exception a service loop survived. deque.append is
        atomic so it stays lock-free, but the counter is a
        read-modify-write and is bumped under the runtime lock (cheap —
        error paths only, and no caller holds another lock here)."""
        self._swallowed.append((where, repr(exc)))
        with self._lock:
            self.stats["errors_swallowed"] += 1

    def swallowed_errors(self) -> list:
        """The last few survived exceptions, newest last."""
        return list(self._swallowed)

    def shutdown(self) -> None:
        """Leave the cluster: graceful byes, close sockets, stop threads.
        Idempotent; does not shut the wrapped ActorSystem down.

        Returns promptly: the heartbeat loop waits on an event rather than
        sleeping through its interval, so a node with a long
        ``heartbeat_interval`` still leaves in milliseconds (regression:
        mesh scale-in used to inherit up to a full interval of latency per
        released node)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            conns = list(self._conns.values())
        self._closed_evt.set()
        for c in conns:
            if c.alive:
                try:
                    self._write(c, ("bye",))
                except Exception:  # lint: best-effort farewell on a closing link
                    pass
        if self._listener is not None:
            try:
                self._listener.close()
            except OSError:
                pass
        for c in conns:
            self._drop_conn(c, NodeDown(f"node {self.name} shut down"),
                            notify=False)
        with self._cv:
            self._cv.notify_all()
        if threading.current_thread() is not self._hb_thread:
            # the event above wakes the loop immediately, so this join is
            # bounded by one liveness sweep, not by heartbeat_interval
            self._hb_thread.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False

    # -- outbound (RemoteActorRef backend) ---------------------------------
    def _conn_for(self, peer: str) -> _Conn:
        with self._lock:
            conn = self._conns.get(peer)
        if conn is None or not conn.alive:
            raise NodeDown(f"no live connection to node {peer!r}")
        return conn

    def _write(self, conn: _Conn, frame: tuple) -> None:
        """Send an envelope frame: primitives plus pre-encoded payload
        blobs only (see ``wire.encode_frame``), so the receiver's envelope
        decode cannot fail on user objects."""
        data = wire.encode_frame(frame)
        try:
            with conn.wlock:
                wire.write_frame(conn.sock, data)
        except OSError as exc:
            self._drop_conn(conn, NodeDown(f"write to {conn.peer} failed: "
                                           f"{exc}"))
            raise NodeDown(f"node {conn.peer} unreachable: {exc}") from exc
        self.stats["frames_out"] += 1

    def _encode_payload(self, obj, consume: bool = False,
                        peer: Optional[str] = None) -> bytes:
        return wire.encode(obj, compress=self.compress, consume=consume,
                           peer=peer)

    def _decode_payload(self, blob: bytes):
        return wire.decode(blob, device=self.unspill_device)

    def _send_to(self, peer: str, rid, payload: tuple) -> None:
        conn = self._conn_for(peer)
        self._write(conn, ("send", rid,
                           self._encode_payload(payload, peer=peer)))

    def _pending_request(self, peer: str, rid, make_frame) -> Future:
        """Shared request/reply plumbing: allocate a req_id, register the
        reply future, write ``make_frame(req_id)``; any failure along the
        way (dead peer, payload encode error) fails the future instead of
        leaking a pending entry. ``rid`` tags actor requests (None for
        node-level rpc) so a runtime-refused reply can mark that actor
        dead.

        Every successful round trip is reported to the placement
        service's wire-cost model (payload bytes + elapsed seconds), so
        the hop-latency/throughput estimates that drive cross-node graph
        placement refine themselves from real traffic. The samples
        include remote compute time, which the model treats as smoothed
        upper bounds."""
        fut: Future = Future()
        req_id = next(self._req_ids)
        with self._lock:
            self._pending[req_id] = (peer, rid, fut)
        try:
            # make_frame also encodes the payload blob, so encode errors
            # fail this future like any other send failure
            frame = make_frame(req_id)
            self._write(self._conn_for(peer), frame)
        except Exception as exc:
            with self._lock:
                self._pending.pop(req_id, None)
            _safe_set_exception(fut, exc if isinstance(exc, ActorFailed)
                                else ActorFailed(str(exc)))
            return fut
        blob = frame[-1]
        nbytes = len(blob) if isinstance(blob, (bytes, bytearray)) else 0
        t0 = time.monotonic()
        compressed = self.compress is True

        def _observe(f: Future) -> None:
            if f.cancelled() or f.exception() is not None:
                return      # failures say nothing about hop cost
            from repro.core.placement import service as placement_service
            placement_service().observe_hop(
                peer, nbytes, time.monotonic() - t0, compressed=compressed)

        fut.add_done_callback(_observe)
        return fut

    def _request_to(self, peer: str, rid, payload: tuple) -> Future:
        return self._pending_request(
            peer, rid, lambda req_id: ("request", req_id, rid,
                                       self._encode_payload(payload,
                                                            peer=peer)))

    def _rpc(self, peer: str, op: str, args: tuple) -> Future:
        return self._pending_request(
            peer, None, lambda req_id: ("rpc", req_id, op,
                                        self._encode_payload(args,
                                                             peer=peer)))

    def _exit_remote(self, ref: RemoteActorRef, reason: Any) -> None:
        self._write(self._conn_for(ref.peer),
                    ("exit", ref.remote_id, self._reason_blob(reason)))

    def _remote_alive(self, peer: str, rid) -> bool:
        with self._lock:
            conn = self._conns.get(peer)
            return (conn is not None and conn.alive
                    and (peer, rid) not in self._dead_remote)

    # -- cross-node supervision -------------------------------------------
    def _monitor_remote(self, ref: RemoteActorRef, watcher: ActorRef) -> None:
        key = (ref.peer, ref.remote_id)
        with self._lock:
            dead = (key in self._dead_remote
                    or ref.peer in self._dead_peers
                    or ref.peer not in self._conns
                    or not self._conns[ref.peer].alive)
            if not dead:
                self._watchers.setdefault(key, []).append(watcher)
                first = key not in self._monitored_out
                self._monitored_out.add(key)
        if dead:
            watcher.send(DownMessage(ref.actor_id,
                                     NodeDown(f"node {ref.peer} is down")))
            return
        if first:
            try:
                self._write(self._conn_for(ref.peer),
                            ("monitor", ref.remote_id))
            except ActorFailed:
                pass  # the drop path already notified the watcher list

    def _link_remote(self, ref: RemoteActorRef, other: ActorRef) -> None:
        if getattr(other, "is_remote", False):
            raise TypeError(
                "linking two remote actors is not supported from a third "
                "node; link on the node that owns one of them")
        key = (ref.peer, ref.remote_id)
        with self._lock:
            dead = key in self._dead_remote or not self._remote_alive(*key)
            if not dead:
                self._link_locals.setdefault(key, []).append(other)
                first = key not in self._linked_out
                self._linked_out.add(key)
        if dead:
            other.send(ExitMessage(ref.actor_id,
                                   NodeDown(f"node {ref.peer} is down")))
            return
        if first:
            try:
                self._write(self._conn_for(ref.peer), ("link", ref.remote_id))
            except ActorFailed:
                return
        # reverse half: when the local side dies, terminate the remote
        # one. One shared relay per (peer, rid) — the ExitMessage names
        # the dying local actor, so every linked local registers the same
        # forwarder (spawning one per call would grow without bound)
        peer, rid = key
        rkey = ("r", peer, rid)
        with self._lock:
            relay = self._relays.get(rkey)
        if relay is None:
            def forward_exit(msg, peer=peer, rid=rid):
                if isinstance(msg, ExitMessage):
                    try:
                        self._write(self._conn_for(peer),
                                    ("exit_to", rid, msg.actor_id,
                                     self._reason_blob(msg.reason)))
                    except ActorFailed:
                        pass

            relay = self.system.spawn(_Relay(forward_exit))
            with self._lock:
                existing = self._relays.setdefault(rkey, relay)
            if existing is not relay:
                relay.exit(None)   # lost a racing registration
                relay = existing
        self.system._link_half(other, relay)

    # -- connection plumbing ----------------------------------------------
    def _register_conn(self, peer: str, sock: socket.socket) -> _Conn:
        conn = _Conn(peer, sock)
        with self._cv:
            old = self._conns.get(peer)
            if old is not None and old.alive:
                sock.close()
                raise ConnectionError(
                    f"a live peer named {peer!r} is already connected")
            self._conns[peer] = conn
            self._dead_peers.discard(peer)
            # a reconnect is a fresh incarnation: its actor ids restart,
            # so per-actor death/registration state from the dead
            # incarnation must not shadow the new one (stale _dead_remote
            # entries would report live actors dead; stale _monitored_out
            # / _relays entries would swallow new registrations)
            self._dead_remote = {k for k in self._dead_remote
                                 if k[0] != peer}
            self._monitored_out = {k for k in self._monitored_out
                                   if k[0] != peer}
            self._linked_out = {k for k in self._linked_out if k[0] != peer}
            stale_relays = [self._relays.pop(k)
                            for k in list(self._relays) if k[1] == peer]
            self._cv.notify_all()
        for r in stale_relays:
            r.exit(None)   # purged from the dict — also stop the actor
        conn.reader = threading.Thread(
            target=self._read_loop, args=(conn,),
            name=f"{self.name}-rx-{peer}", daemon=True)
        conn.reader.start()
        return conn

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return  # listener closed
            try:
                sock.settimeout(30.0)
                data = wire.read_frame(sock)
                frame = wire.decode_frame(data) if data else None
                if not frame or frame[0] != "hello":
                    sock.close()
                    continue
                wire.write_frame(sock, wire.encode_frame(("hello", self.name)))
                sock.settimeout(None)
                self._register_conn(frame[1], sock)
            except Exception as exc:
                # a failed handshake must not kill the accept loop, but
                # the fault stays visible in peer_stats
                self._note_error("accept", exc)
                try:
                    sock.close()
                except OSError:
                    pass

    def _read_loop(self, conn: _Conn) -> None:
        def touch():
            # liveness = bytes arriving, not frames completing: a large
            # spill mid-transfer must not read as missed heartbeats
            conn.last_rx = time.monotonic()

        while conn.alive:
            try:
                data = wire.read_frame(conn.sock, on_chunk=touch)
            except (OSError, ConnectionError) as exc:
                self._drop_conn(conn, NodeDown(
                    f"connection to {conn.peer} failed: {exc}"))
                return
            if data is None:
                self._drop_conn(conn, NodeDown(
                    f"node {conn.peer} closed the connection"))
                return
            conn.last_rx = time.monotonic()
            self.stats["frames_in"] += 1
            try:
                frame = wire.decode_frame(data)
            except Exception as exc:
                # envelope frames are primitives-only, so this is a rare
                # malformed/incompatible control frame (e.g. an exotic
                # failure reason) — framing is length-prefixed, the stream
                # is still in sync: skip it rather than killing every
                # in-flight request on a healthy link
                self.stats["frames_bad"] += 1
                self._note_error(f"decode from {conn.peer}", exc)
                continue
            tag = frame[0]
            if tag == "ping":
                try:
                    self._write(conn, ("pong",))
                except ActorFailed:
                    return
                continue
            if tag == "pong":
                continue
            if tag == "bye":
                self._drop_conn(conn, NodeDown(
                    f"node {conn.peer} left the cluster"))
                return
            # everything else is ordered through the broker actor
            self._broker.send(conn.peer, frame)

    def _heartbeat_loop(self) -> None:
        # wait(interval) instead of time.sleep(interval): shutdown() sets
        # the event, so the loop exits immediately instead of finishing a
        # blind sleep first (slow-shutdown regression)
        while not self._closed_evt.wait(self.heartbeat_interval):
            with self._lock:
                conns = [c for c in self._conns.values() if c.alive]
            now = time.monotonic()
            for c in conns:
                if now - c.last_rx > self.heartbeat_timeout:
                    self._drop_conn(c, NodeDown(
                        f"node {c.peer} missed heartbeats for "
                        f"{now - c.last_rx:.1f}s"))
                    continue
                try:
                    self._write(c, ("ping",))
                except ActorFailed:
                    pass  # _write already dropped the conn

    def _drop_conn(self, conn: _Conn, reason: Exception,
                   notify: bool = True) -> None:
        """Peer death: fail its pending futures, deliver DownMessage /
        ExitMessage to local monitors/links of its actors. Idempotent."""
        with self._cv:
            if not conn.alive:
                return
            conn.alive = False
            self._dead_peers.add(conn.peer)
            self.stats["peers_lost"] += 1
            pending = [(k, v) for k, v in self._pending.items()
                       if v[0] == conn.peer]
            for k, _ in pending:
                self._pending.pop(k, None)
            watchers = [(key, refs) for key, refs in self._watchers.items()
                        if key[0] == conn.peer]
            for key, _ in watchers:
                self._watchers.pop(key, None)
            links = [(key, refs) for key, refs in self._link_locals.items()
                     if key[0] == conn.peer]
            for key, _ in links:
                self._link_locals.pop(key, None)
            # relays serving (or forwarding to) the dead peer have nothing
            # left to forward — stop the actors, don't just forget them
            relays = [self._relays.pop(k)
                      for k in list(self._relays) if k[1] == conn.peer]
            self._cv.notify_all()
        try:
            conn.sock.close()
        except OSError:
            pass
        for r in relays:
            r.exit(None)
        for _, (peer, rid, fut) in pending:
            # _safe_set_exception loses the race to a concurrent reply
            # silently — that is the legal outcome, not a hidden fault
            _safe_set_exception(fut, NodeDown(
                f"request to {peer}/{rid} lost: {reason}"))
        if not notify:
            return
        for (peer, rid), refs in watchers:
            for w in refs:
                w.send(DownMessage(f"{peer}/{rid}", reason))
        for (peer, rid), refs in links:
            for l in refs:
                l.send(ExitMessage(f"{peer}/{rid}", reason))

    # -- inbound frame handling (broker-ordered) ----------------------------
    def _resolve(self, rid) -> Optional[int]:
        if isinstance(rid, str):
            with self._lock:
                ref = self._published.get(rid)
            return ref.actor_id if ref is not None else None
        return rid

    def _handle(self, peer: str, frame: tuple) -> None:
        tag = frame[0]
        handler = getattr(self, f"_on_{tag}", None)
        if handler is None:
            return  # unknown frame: forward compatibility
        handler(peer, *frame[1:])

    def _on_send(self, peer: str, rid, blob: bytes) -> None:
        aid = self._resolve(rid)
        if aid is None:
            return
        try:
            payload = self._decode_payload(blob)
        except Exception as exc:
            self.stats["frames_bad"] += 1   # fire-and-forget: drop it
            self._note_error(f"send-payload from {peer}", exc)
            return
        self.system._enqueue(aid, Message(tuple(payload), None, None))

    def _on_request(self, peer: str, req_id: int, rid, blob: bytes) -> None:
        aid = self._resolve(rid)
        fut: Future = Future()
        fut.add_done_callback(
            lambda f: self._send_reply(peer, req_id, f, target_aid=aid))
        if aid is None:
            fut.set_exception(ActorFailed(
                f"node {self.name} has no actor {rid!r}"))
            return
        try:
            payload = self._decode_payload(blob)
        except Exception as exc:
            # a payload only this request can't use (e.g. a behavior class
            # unimportable here) fails this request, not the connection
            fut.set_exception(PayloadError(
                f"node {self.name} could not decode the payload for "
                f"{rid!r}: {exc!r}"))
            return
        self.system._enqueue(aid, Message(tuple(payload), fut, None))

    def _send_reply(self, peer: str, req_id: int, fut: Future,
                    target_aid=_RPC_TARGET) -> None:
        if fut.cancelled():
            ok, value = False, _safe_reason(ActorFailed("request cancelled"))
        else:
            exc = fut.exception()
            if exc is not None:
                ok, value = False, _safe_reason(exc)
            else:
                ok, value = True, fut.result()
        # liveness sampled at reply time: a behavior exception has already
        # terminated the target by now, while a failed *delegated* promise
        # (or a decode error) leaves it alive — this flag, not the error
        # type, is what tells the requester whether to mark the remote
        # actor dead
        if target_aid is _RPC_TARGET:
            alive = True
        else:
            alive = target_aid is not None and self.system._is_alive(target_aid)
        try:
            conn = self._conn_for(peer)
        except ActorFailed:
            return
        try:
            # consume=True: reply refs transfer ownership — spilled in
            # place so the sender's device buffer is dropped at the wire
            blob = self._encode_payload(value, consume=True, peer=peer)
        except Exception as exc:   # unserializable result
            ok, blob = False, self._encode_payload(_safe_reason(exc))
        try:
            self._write(conn, ("reply", req_id, ok, blob, alive))
        except ActorFailed:
            pass

    def _on_reply(self, peer: str, req_id: int, ok: bool, blob: bytes,
                  alive: bool = True) -> None:
        with self._lock:
            entry = self._pending.pop(req_id, None)
        if entry is None:
            return
        _, rid, fut = entry
        try:
            value = self._decode_payload(blob)
        except Exception as exc:
            ok, value = False, PayloadError(
                f"reply from {peer} could not be decoded: {exc!r}")
        if not alive and rid is not None:
            with self._lock:
                self._dead_remote.add((peer, rid))
        if ok:
            _safe_set_result(fut, value)
        else:
            _safe_set_exception(
                fut, value if isinstance(value, BaseException)
                else ActorFailed(repr(value)))

    def _on_rpc(self, peer: str, req_id: int, op: str, blob: bytes) -> None:
        fut: Future = Future()
        fut.add_done_callback(lambda f: self._send_reply(peer, req_id, f))
        try:
            args = self._decode_payload(blob)
        except Exception as exc:
            fut.set_exception(PayloadError(
                f"node {self.name} could not decode rpc payload: {exc!r}"))
            return
        try:
            if op == "spawn":
                # older peers send a 3-tuple (no spawn kwargs)
                behavior, sp_args, publish = args[:3]
                sp_kwargs = args[3] if len(args) > 3 else {}
                ref = self.system.spawn(behavior, *sp_args, **sp_kwargs)
                if publish:
                    self.publish(publish, ref)
                fut.set_result(ref.actor_id)
            elif op == "lookup":
                (name,) = args
                with self._lock:
                    ref = self._published.get(name)
                if ref is None:
                    raise LookupError(
                        f"node {self.name} publishes no actor named "
                        f"{name!r}; available: {sorted(self._published)}")
                fut.set_result(ref.actor_id)
            elif op == "stats":
                from repro.core.memref import memory_stats
                snap = memory_stats()
                snap["errors_swallowed"] = self.stats["errors_swallowed"]
                snap["swallowed_errors"] = self.swallowed_errors()
                with self._lock:
                    providers = dict(self._stats_providers)
                for pname, pfn in providers.items():
                    try:
                        snap[pname] = pfn()
                    except Exception as exc:
                        # one broken provider must not cost the whole
                        # stats reply (routers poll this on every tick)
                        snap[pname] = {"error": repr(exc)}
                fut.set_result(snap)
            else:
                raise ValueError(f"unknown rpc op {op!r}")
        except Exception as exc:
            fut.set_exception(exc)

    def _reason_blob(self, reason: Any) -> bytes:
        """Failure reasons are arbitrary user exceptions, so they travel
        as payload blobs like every other user object — never in the
        primitives-only envelope, where a receiver-undecodable reason
        would cost the whole death notification."""
        return self._encode_payload(_safe_reason(reason))

    def _decode_reason(self, peer: str, blob: bytes) -> Any:
        try:
            return self._decode_payload(blob)
        except Exception as exc:
            # the notification must survive even if its reason doesn't
            return PayloadError(
                f"failure reason from {peer} could not be decoded: {exc!r}")

    def _on_exit(self, peer: str, rid, blob: bytes) -> None:
        aid = self._resolve(rid)
        if aid is not None:
            self.system._terminate(aid, self._decode_reason(peer, blob))

    def _on_exit_to(self, peer: str, rid, from_key, blob: bytes) -> None:
        """The peer's side of a link died: deliver an ExitMessage into the
        local target's mailbox (trap_exit-aware via the normal path)."""
        aid = self._resolve(rid)
        if aid is not None:
            ActorRef(aid, self.system).send(
                ExitMessage(f"{peer}/{from_key}",
                            self._decode_reason(peer, blob)))

    def _register_relay(self, peer: str, rid, kind: str) -> None:
        """Serve a peer's monitor ('m') or link ('l') registration for
        local actor ``rid``: spawn (once per key) an exit-trapping relay
        that forwards the death event home as a wire frame, and register
        it through the same locked runtime paths local supervision uses —
        so an already-dead (or unknown) target fires immediately."""
        msg_type, evt_tag = ((DownMessage, "down_evt") if kind == "m"
                             else (ExitMessage, "exit_evt"))
        key = (kind, peer, rid)
        with self._lock:
            if key in self._relays:
                return

        def forward(msg, peer=peer, rid=rid):
            if isinstance(msg, msg_type):
                try:
                    self._write(self._conn_for(peer),
                                (evt_tag, rid, self._reason_blob(msg.reason)))
                except ActorFailed:
                    pass

        relay = self.system.spawn(_Relay(forward))
        with self._lock:
            self._relays[key] = relay
        aid = self._resolve(rid)
        if aid is None:
            relay.send(msg_type(rid, ActorFailed(
                f"node {self.name} has no actor {rid!r}")))
            return
        target = ActorRef(aid, self.system)
        if kind == "m":
            self.system.monitor(relay, target)
        else:
            self.system._link_half(target, relay)

    def _on_monitor(self, peer: str, rid) -> None:
        self._register_relay(peer, rid, "m")

    def _on_link(self, peer: str, rid) -> None:
        self._register_relay(peer, rid, "l")

    def _on_down_evt(self, peer: str, rid, blob: bytes) -> None:
        key = (peer, rid)
        with self._lock:
            self._dead_remote.add(key)
            refs = self._watchers.pop(key, [])
        reason = self._decode_reason(peer, blob)
        for w in refs:
            w.send(DownMessage(f"{peer}/{rid}", reason))

    def _on_exit_evt(self, peer: str, rid, blob: bytes) -> None:
        key = (peer, rid)
        with self._lock:
            self._dead_remote.add(key)
            refs = self._link_locals.pop(key, [])
        reason = self._decode_reason(peer, blob)
        for l in refs:
            l.send(ExitMessage(f"{peer}/{rid}", reason))

    def __repr__(self):
        return (f"NodeRuntime({self.name!r}, peers={self.peers()}, "
                f"published={sorted(self._published)})")
