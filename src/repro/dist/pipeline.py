"""Pipeline parallelism from stage actors (DESIGN.md §4).

``make_layer_stage_actors`` slices a model's layer stack into contiguous
stages, each owned by one actor (one mesh slice at pod scale); the
:class:`PipelineRunner` streams microbatches through the stage chain with
a bounded in-flight depth — the paper's async event-chaining (Listing 4)
applied to 1F pipeline schedules: stage *n+1* of microbatch *i* overlaps
stage *n* of microbatch *i+1*.

The stage chain itself is built with the unified
:class:`repro.core.Pipeline` surface (``mode="staged"``), so the same
composition object covers kernel actors and model stages.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import ActorRef, ActorSystem
from repro.core.api import Pipeline
from repro.core.memref import DeviceRef, as_device_array
from repro.models.layers import apply_norm
from repro.models.transformer import embed_inputs, layer_groups, _apply_unit

__all__ = ["PipelineRunner", "make_layer_stage_actors"]


# ----------------------------------------------------------------------------
# stage construction
# ----------------------------------------------------------------------------
def _positions_for(cfg, b: int, s: int):
    base = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return jnp.broadcast_to(base, (3, b, s)) if cfg.m_rope else base


def _stage_fn(model, chunk_units, first: bool, last: bool,
              embed, final_norm, head):
    """A pure ``(chunk_params, x) → x`` function for one stage.

    The first stage embeds tokens; the last applies the final norm and LM
    head. Middle stages are pure residual-stream transforms, so only the
    [B, S, D] activation crosses actor boundaries."""
    cfg = model.cfg

    def stage(chunk_params, x):
        if first:
            tokens = x
            b, s = tokens.shape
            x = embed_inputs({"embed": embed}, cfg, tokens, None)
        else:
            b, s = x.shape[0], x.shape[1]
        positions = _positions_for(cfg, b, s)
        aux = jnp.zeros((), jnp.float32)
        for unit, lp in zip(chunk_units, chunk_params):
            x, aux = _apply_unit(lp, cfg, unit, x, positions, aux,
                                 model.attn_impl)
        if last:
            x = apply_norm(final_norm, x, cfg.norm)
            h = embed.T if cfg.tie_embeddings else head
            return x @ h.astype(x.dtype)
        return x

    return stage


def make_layer_stage_actors(system: ActorSystem, model, params,
                            n_stages: int) -> List[ActorRef]:
    """Split the layer stack into ``n_stages`` contiguous stage actors.

    The staged forward reproduces ``model.forward`` exactly (same per-layer
    ops in the same order); only the logits (not the MoE aux loss) leave
    the last stage."""
    cfg = model.cfg
    if cfg.family == "encdec":
        raise NotImplementedError("stage split targets decoder-only stacks")
    units: list = []  # (unit kinds, per-layer params)
    for gi, (unit, count) in enumerate(layer_groups(cfg)):
        gp = params["groups"][gi]
        for ci in range(count):
            units.append((unit, jax.tree.map(lambda a, ci=ci: a[ci], gp)))
    n_layers = len(units)
    if not 1 <= n_stages <= n_layers:
        raise ValueError(f"n_stages={n_stages} not in [1, {n_layers}]")
    sizes = [n_layers // n_stages + (1 if i < n_layers % n_stages else 0)
             for i in range(n_stages)]
    head = params.get("head")
    stages, lo = [], 0
    for si, sz in enumerate(sizes):
        chunk = units[lo:lo + sz]
        last = si == n_stages - 1
        lo += sz
        fn = _stage_fn(model, [u for u, _ in chunk],
                       first=(si == 0), last=last,
                       embed=params["embed"],
                       final_norm=params["final_norm"], head=head)
        jitted = jax.jit(fn)
        chunk_params = [p for _, p in chunk]

        # stages speak DeviceRef natively: inputs are unwrapped (host
        # microbatches are transferred once, by the first stage) and the
        # [B, S, D] activation crosses actor boundaries as a ref — the
        # composed chain releases it once the next stage has consumed it
        def _stage(x, _f=jitted, _p=chunk_params, _last=last):
            y = _f(_p, as_device_array(x))
            return y if _last else DeviceRef(y)

        stages.append(system.spawn(_stage))
    return stages


# ----------------------------------------------------------------------------
# microbatch streaming
# ----------------------------------------------------------------------------
class PipelineRunner:
    """Streams microbatches through a stage chain with ≤ ``depth`` in
    flight; results come back in submission order and the first stage
    failure aborts the run."""

    def __init__(self, system: ActorSystem, stages: Sequence[ActorRef],
                 depth: int = 2):
        if not stages:
            raise ValueError("need at least one stage")
        self.depth = depth
        self._chain = Pipeline(system, mode="staged").stages(stages).build()

    def run(self, microbatches: Sequence[Any],
            timeout: Optional[float] = 300.0, emit: str = "value") -> list:
        """Stream the microbatches; returns results in submission order.

        Microbatches may be host arrays **or** :class:`DeviceRef`\\ s (the
        first stage unwraps refs, so data already on device never bounces
        through the host). ``emit`` selects the result representation:

        * ``"value"`` — whatever the last stage produced (default);
        * ``"ref"``   — wrap each result as a :class:`DeviceRef`, the
          stay-on-device handoff to a downstream consumer;
        * ``"spill"`` — wrap **and spill**: the explicit host-serialization
          stage boundary (paper §3.5 option (b)) for cross-node transport —
          spilled refs pickle.
        """
        if emit not in ("value", "ref", "spill"):
            raise ValueError(f"emit must be value|ref|spill, got {emit!r}")
        sem = threading.Semaphore(self.depth)
        results: list = [None] * len(microbatches)
        first_error: list = [None]
        futures = []
        for i, mb in enumerate(microbatches):
            sem.acquire()
            if first_error[0] is not None:
                sem.release()
                break
            payload = mb if isinstance(mb, tuple) else (mb,)
            fut = self._chain.request(*payload)

            def _done(f, i=i):
                exc = f.exception()
                if exc is not None:
                    if first_error[0] is None:
                        first_error[0] = exc
                else:
                    res = f.result()
                    if emit != "value":
                        ref = (res if isinstance(res, DeviceRef)
                               else DeviceRef(jnp.asarray(res)))
                        if emit == "spill":
                            ref.spill()
                        res = ref
                    results[i] = res
                sem.release()

            fut.add_done_callback(_done)
            futures.append(fut)
        for f in futures:
            try:
                f.result(timeout)
            except Exception:
                pass  # recorded by the callback; first error wins
        if first_error[0] is not None:
            raise first_error[0]
        return results
