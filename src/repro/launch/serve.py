"""Serving launcher: a thin CLI over :class:`repro.serve.ServeEngine`.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --requests 32 --batch 8 --steps 64

Each request decodes ``--steps`` greedy tokens against its own
device-resident cache; the engine batches requests (gang-scheduled — the
model cache carries a batch-uniform decode position, so mid-batch joins
are disabled) and reports per-request p50/p95/p99 latency plus the
DeviceRef traffic counters. ``--sync`` keeps the legacy single-process
loop (also the only path for encoder–decoder models, whose cache needs
per-request encoder frames).
"""
from __future__ import annotations

import argparse
import time

__all__ = ["main", "check_cache_capacity"]


def check_cache_capacity(steps: int, capacity: int) -> int:
    """Guard the decode length against the allocated cache.

    A decode of ``steps`` tokens occupies ``steps + 1`` cache slots (the
    prompt token plus one per generated token); a longer decode would
    silently wrap the ring buffer / overwrite live KV entries instead of
    failing loudly. Returns ``capacity`` so call sites can chain it.
    """
    if steps < 0:
        raise ValueError(f"steps must be >= 0, got {steps}")
    if steps + 1 > capacity:
        raise ValueError(
            f"decode of {steps} steps needs {steps + 1} cache slots but "
            f"only {capacity} were allocated; raise the cache capacity or "
            "shorten the decode")
    return capacity


def _run_engine(args, cfg, model, params, serve_step) -> int:
    import jax.numpy as jnp
    import numpy as np
    from repro.core import ActorSystem, memory_stats
    from repro.serve import ServeEngine

    capacity = args.steps + 1
    check_cache_capacity(args.steps, capacity)

    def step_fn(cache, tokens):
        nxt, _, cache = serve_step(params, cache, tokens[:, None])
        return nxt[:, 0], cache

    def init_fn(prompt):
        return model.init_cache(1, capacity), int(prompt)

    # Per-leaf batch axis, detected by diffing abstract cache shapes for
    # batch sizes 1 and 2 (layer-scanned leaves carry the layer count on
    # axis 0 and batch on axis 1). Leaves with no batch axis — the scalar
    # decode position — are batch-uniform and shared, which gang
    # scheduling keeps aligned.
    import jax
    s1 = jax.tree_util.tree_leaves(
        jax.eval_shape(lambda: model.init_cache(1, capacity)))
    s2 = jax.tree_util.tree_leaves(
        jax.eval_shape(lambda: model.init_cache(2, capacity)))
    batch_axes = [next((ax for ax, (a, b) in enumerate(zip(x.shape, y.shape))
                        if a != b), None)
                  for x, y in zip(s1, s2)]

    def combine(leaves, i):
        ax = batch_axes[i]
        return leaves[0] if ax is None else jnp.concatenate(leaves, axis=ax)

    def split(leaf, b, i):
        ax = batch_axes[i]
        if ax is None:
            return leaf
        return jax.lax.slice_in_dim(leaf, b, b + 1, axis=ax)

    with ActorSystem(name="serve") as system:
        engine = ServeEngine(system, step_fn, init_fn,
                             n_workers=args.workers, max_batch=args.batch,
                             allow_join=False, combine=combine, split=split)
        t0 = time.perf_counter()
        with engine:
            futs = [engine.submit(0, max_new_tokens=args.steps)
                    for _ in range(args.requests)]
            results = [f.result(timeout=600) for f in futs]
        dt = time.perf_counter() - t0
        stats = engine.stats()
    lat = stats["latency"]
    toks = sum(len(r.tokens) for r in results)
    print(f"{cfg.name}: {args.requests} requests × {args.steps} steps "
          f"(batch {args.batch}, {args.workers} workers) in {dt:.2f}s "
          f"({toks / dt:,.0f} tok/s)")
    print(f"latency p50={lat['p50_ms']:.1f}ms p95={lat['p95_ms']:.1f}ms "
          f"p99={lat['p99_ms']:.1f}ms | engine steps={stats['steps']} "
          f"requeues={stats['requeues']}")
    print("memref:", {k: v for k, v in memory_stats().items()
                      if k in ("transfers", "readbacks", "live_refs")})
    print("sample:", np.asarray(results[0].tokens)[:16].tolist())
    return 0


def _run_sync(args, cfg, model, params, serve_step) -> int:
    import jax.numpy as jnp
    import numpy as np

    capacity = args.steps + 1
    check_cache_capacity(args.steps, capacity)
    if cfg.family == "encdec":
        rng = np.random.default_rng(0)
        frames = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.encdec.n_frames, cfg.d_model)),
            jnp.dtype(cfg.compute_dtype))
        cache = model.init_cache(args.batch, capacity, params=params,
                                 frames=frames)
    else:
        cache = model.init_cache(args.batch, capacity)

    toks = jnp.zeros((args.batch, 1), jnp.int32)
    outs = []
    t0 = time.perf_counter()
    for _ in range(args.steps):
        toks, _, cache = serve_step(params, cache, toks)
        outs.append(np.asarray(toks))
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: {args.steps} steps × {args.batch} requests "
          f"in {dt:.2f}s ({args.steps * args.batch / dt:,.0f} tok/s)")
    print("sample:", np.concatenate(outs, axis=1)[0, :16].tolist())
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=32,
                    help="engine mode: how many requests to serve")
    ap.add_argument("--batch", type=int, default=8,
                    help="max batch size (sync mode: the static batch)")
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--workers", type=int, default=2,
                    help="engine mode: decode worker replicas")
    ap.add_argument("--sync", action="store_true",
                    help="legacy synchronous loop instead of the engine")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)

    import jax
    from repro import configs
    from repro.dist import step as step_mod
    from repro.models import Model

    cfg = (configs.get_config if args.full else configs.get_smoke_config)(
        args.arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    if args.sync or cfg.family == "encdec":
        serve_step = jax.jit(step_mod.build_serve_step(model),
                             donate_argnums=(1,))
        return _run_sync(args, cfg, model, params, serve_step)
    # engine mode: the worker jits the batched step itself (and retries
    # must be able to replay a cache, so no donation here)
    serve_step = step_mod.build_serve_step(model)
    return _run_engine(args, cfg, model, params, serve_step)


if __name__ == "__main__":
    raise SystemExit(main())
