"""Serving launcher: batched greedy decode against a resident cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --batch 8 --steps 64
"""
from __future__ import annotations

import argparse
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import configs
    from repro.dist import step as step_mod
    from repro.models import Model

    cfg = (configs.get_config if args.full else configs.get_smoke_config)(
        args.arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    serve_step = jax.jit(step_mod.build_serve_step(model), donate_argnums=(1,))

    if cfg.family == "encdec":
        rng = np.random.default_rng(0)
        frames = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.encdec.n_frames, cfg.d_model)),
            jnp.dtype(cfg.compute_dtype))
        cache = model.init_cache(args.batch, args.steps + 1, params=params,
                                 frames=frames)
    else:
        cache = model.init_cache(args.batch, args.steps + 1)

    toks = jnp.zeros((args.batch, 1), jnp.int32)
    outs = []
    t0 = time.perf_counter()
    for _ in range(args.steps):
        toks, _, cache = serve_step(params, cache, toks)
        outs.append(np.asarray(toks))
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: {args.steps} steps × {args.batch} requests "
          f"in {dt:.2f}s ({args.steps * args.batch / dt:,.0f} tok/s)")
    print("sample:", np.concatenate(outs, axis=1)[0, :16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
