"""repro: OpenCL-actor-style data-parallel runtime + LM framework in JAX.

Paper: "OpenCL Actors — Adding Data Parallelism to Actor-based Programming
with CAF" (Hiesgen, Charousset, Schmidt; Agere/LNCS 2017), adapted to
JAX/TPU. See DESIGN.md.
"""
__version__ = "0.1.0"
