"""Repo-level pytest config.

Installs the vendored ``repro._compat.minihypothesis`` under the
``hypothesis`` name when the real library is not importable, so
``tests/test_property.py`` collects and runs in hermetic containers.
The real package always wins when present.

With ``REPRO_ANALYSIS=1`` this file is also the dynamic-analysis pytest
plugin (see ``repro.analysis.runtime``):

* every runtime lock is a ``TrackedLock``/``TrackedRLock`` (the
  ``make_lock`` seam reads the env var at construction), so
  ordering violations raise inside the offending test;
* a per-test **DeviceRef leak sentinel** fails any test that ends with
  more live refs than it started with (opt out with
  ``@pytest.mark.ref_leak_ok`` for tests that intentionally leak);
* the terminal summary prints the observed lock-order graph and fails
  the session if any acquisition cycle or recorded violation survived.
"""
import importlib.util
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))

if importlib.util.find_spec("hypothesis") is None:
    from repro._compat import minihypothesis as _mh

    sys.modules["hypothesis"] = _mh
    sys.modules["hypothesis.strategies"] = _mh.strategies


def _analysis_on() -> bool:
    from repro.analysis.runtime import analysis_enabled
    return analysis_enabled()


@pytest.fixture(autouse=True)
def _device_ref_leak_sentinel(request):
    """Fail a test that leaks DeviceRefs (REPRO_ANALYSIS=1 only).

    Autouse fixtures set up first and tear down *last*, so every other
    function-scoped fixture (actor systems, pools, engines) has already
    released its refs by the time the check runs. The settle loop gives
    GC and in-flight done-callbacks a moment to catch up before calling
    growth a leak.
    """
    if not _analysis_on():
        yield
        return
    if request.node.get_closest_marker("ref_leak_ok"):
        yield
        return
    from repro.core.memref import live_ref_count

    from repro.analysis.runtime import settled_ref_growth

    before = live_ref_count()
    yield
    growth = settled_ref_growth(before)
    if growth > 0:
        pytest.fail(
            f"DeviceRef leak: {growth} ref(s) still live after the test "
            f"(started at {before}) — release/donate them or mark the "
            "test with @pytest.mark.ref_leak_ok", pytrace=False)


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    if not _analysis_on():
        return
    from repro.analysis.runtime import (lock_order_cycles, lock_order_graph,
                                        recorded_violations,
                                        same_name_nestings)

    tr = terminalreporter
    graph = lock_order_graph()
    cycles = lock_order_cycles()
    violations = recorded_violations()
    tr.write_sep("-", "repro.analysis lock-order summary")
    if not graph:
        tr.write_line("no nested lock acquisitions observed")
    for a, bs in sorted(graph.items()):
        for b, site in sorted(bs.items()):
            tr.write_line(f"  {a} -> {b}  (first seen {site})")
    for name, site in sorted(same_name_nestings().items()):
        tr.write_line(f"  same-name nesting: {name} ({site})")
    for v in violations:
        tr.write_line(f"  VIOLATION: {v}")
    for c in cycles:
        tr.write_line(f"  CYCLE: {' -> '.join(c)}")
    tr.write_line(f"{len(graph)} source lock(s), {len(cycles)} cycle(s), "
                  f"{len(violations)} violation(s)")


def pytest_sessionfinish(session, exitstatus):
    """A cycle or recorded violation fails the session even if every
    individual test swallowed the raised LockOrderViolation."""
    if not _analysis_on():
        return
    from repro.analysis.runtime import lock_order_cycles, recorded_violations

    if lock_order_cycles() or recorded_violations():
        session.exitstatus = 1
