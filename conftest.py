"""Repo-level pytest config.

Installs the vendored ``repro._compat.minihypothesis`` under the
``hypothesis`` name when the real library is not importable, so
``tests/test_property.py`` collects and runs in hermetic containers.
The real package always wins when present.
"""
import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))

if importlib.util.find_spec("hypothesis") is None:
    from repro._compat import minihypothesis as _mh

    sys.modules["hypothesis"] = _mh
    sys.modules["hypothesis.strategies"] = _mh.strategies
