"""Paper §4 — WAH bitmap indexing on the device.

Builds the full index with the data-parallel pipeline (radix sort →
literals/fills → fuseFillsLiterals as a composed 3-actor pipeline →
lookup table), then verifies a few bitmaps by decoding them back to
position lists. Run:

    PYTHONPATH=src python examples/wah_indexing.py [n_values]
"""
import sys
import time

import numpy as np

import jax.numpy as jnp

from repro.core import ActorSystem
from repro.indexing import (build_wah_index, decode_wah_bitmap,
                            wah_index_pipeline_actors)


def main() -> None:
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 1 << 17
    card = 64
    rng = np.random.default_rng(0)
    values = rng.integers(0, card, n).astype(np.uint32)

    t0 = time.perf_counter()
    words, n_words, starts, counts = build_wah_index(jnp.asarray(values), card)
    n_words.block_until_ready()
    dt = time.perf_counter() - t0
    words = np.asarray(words)[:int(n_words)]
    print(f"indexed {n} values → {int(n_words)} WAH words in {dt:.3f}s "
          f"({n / dt / 1e6:.2f} Mvals/s)")

    # verify a few bitmaps round-trip
    for v in (0, card // 2, card - 1):
        got = decode_wah_bitmap(words, int(np.asarray(starts)[v]),
                                int(np.asarray(counts)[v]))
        want = np.flatnonzero(values == v)
        assert np.array_equal(got, want), v
    print("bitmap round-trip verified for 3 values")

    # paper Listing 5: the same fuse step as a Pipeline of kernel actors
    # (v2 API; staged mode keeps intermediates device-resident)
    with ActorSystem() as system:
        k = 1 << 12
        fills = (rng.integers(0, 2, k) * ((1 << 31) | rng.integers(1, 99, k))
                 ).astype(np.uint32)
        lits = rng.integers(1, 2 ** 31, k).astype(np.uint32)
        pipe = wah_index_pipeline_actors(system, k, mode="staged")
        out, total = pipe.ask(fills, lits)
        print(f"fuseFillsLiterals actor pipeline: {2 * k} slots → "
              f"{int(total)} words (zeros compacted)")


if __name__ == "__main__":
    main()
