"""Paper §5.4 — heterogeneous fractional offload of a Mandelbrot frame.

Two worker pools (a slow 'host' oracle and the Pallas 'device' kernel)
render row slices of one image; the device fraction is swept 0→100 %.
Also demonstrates the chunk scheduler's straggler re-issue. Run:

    PYTHONPATH=src python examples/mandelbrot_offload.py
"""
import time

import numpy as np

from repro.core import ActorSystem, ChunkScheduler, split_offload
from repro.kernels import ops

W, H, IT = 256, 64, 60
VIEW = dict(re_min=-2.0, re_max=0.6, im_min=-1.2, im_max=1.2)
SHADES = " .:-=+*#%@"


def render(start: int, rows: int, impl: str) -> np.ndarray:
    return np.asarray(ops.mandelbrot(height=rows, width=W, max_iter=IT,
                                     row_offset=start, total_height=H,
                                     impl=impl, **VIEW))


def main() -> None:
    with ActorSystem() as system:
        host = system.spawn(lambda s, n: render(s, n, "ref"))
        dev = system.spawn(lambda s, n: render(s, n, "pallas"))

        print("fraction sweep (device share → wall time):")
        img = None
        for pct in (0, 50, 100):
            frac = pct / 100
            t0 = time.perf_counter()
            img = split_offload(
                [dev, host], [frac, 1 - frac],
                make_payload=lambda s, n: (s, n),
                sizes_of=lambda fr: [round(H * fr[0]), H - round(H * fr[0])],
                combine=lambda parts: np.vstack(parts))
            print(f"  {pct:3d}% device: {time.perf_counter() - t0:.3f}s")

        # chunked pull scheduling with straggler re-issue (8 row-chunks)
        sched = ChunkScheduler([host, dev], straggler_factor=2.0)
        rows = H // 8
        t0 = time.perf_counter()
        parts = sched.run([(i * rows, rows) for i in range(8)])
        img2 = np.vstack(parts)
        print(f"chunk-scheduled render: {time.perf_counter() - t0:.3f}s, "
              f"stats={sched.stats}")
        assert img2.shape == img.shape

        # ASCII art, 4x downsampled
        down = img2[::4, ::4]
        for row in down:
            print("".join(SHADES[min(int(v) * len(SHADES) // (IT + 1),
                                     len(SHADES) - 1)] for v in row))


if __name__ == "__main__":
    main()
