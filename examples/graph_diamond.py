"""Typed dataflow-graph composition demo (paper §3.5, ISSUE 4).

Builds the acceptance diamond —

    source ──► broadcast(2) ──► double ──► zip_join ──► add2 (sink)
                        └─────► sub3  ──────┘

— checks that the topology validates at build time, runs it with zero
host transfers on interior edges, and then shows a build-time type error
being caught before anything is spawned.

Run:  PYTHONPATH=src python examples/graph_diamond.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (ActorSystem, Graph, In, NDRange, Out,
                        PortTypeMismatchError, dim_vec, kernel,
                        memory_stats, reset_transfer_stats, transfer_count)

N = 1024


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)))
def double(x):
    return x * 2.0


@kernel(In(jnp.float32), Out(jnp.float32), nd_range=NDRange(dim_vec(N)))
def sub3(x):
    return x - 3.0


@kernel(In(jnp.float32), In(jnp.float32), Out(jnp.float32),
        nd_range=NDRange(dim_vec(N)))
def add2(a, b):
    return a + b


def main() -> None:
    with ActorSystem(max_workers=8) as system:
        g = Graph(system, name="diamond")
        x = g.source("x", jnp.float32, shape=(N,))
        left, right = g.broadcast(x, 2)
        j1, j2 = g.zip_join(g.apply(double, left), g.apply(sub3, right))
        g.output(g.apply(add2, j1, j2))

        diamond = g.build()          # validate → place → lower → spawn
        print("placements:", {k: v.name for k, v in diamond.placements.items()})

        xs = np.arange(N, dtype=np.float32)
        reset_transfer_stats()
        out = diamond.ask(xs)
        np.testing.assert_allclose(out, xs * 2 + xs - 3, rtol=1e-6)
        print(f"diamond ok: transfers={transfer_count()} "
              f"readbacks={memory_stats()['readbacks']} "
              "(interior edges stayed device-resident)")

        # the typed-actor check the paper gets from CAF: wiring an int32
        # source into a float32 kernel fails at *build* time, with the
        # offending node path in the message
        bad = Graph(system, name="bad")
        s = bad.source("x", jnp.int32, shape=(N,))
        bad.output(bad.apply(double, s))
        try:
            bad.build()
        except PortTypeMismatchError as exc:
            print(f"caught at build time: {exc}")


if __name__ == "__main__":
    main()
