"""Quickstart — the paper's Listings 1+2 in this framework (v2 API).

An OpenCL actor multiplying two square matrices: declare the kernel with
``@kernel`` (signature + ND-range captured at definition site), spawn it
directly from the actor system, send the matrices, receive the product:

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax.numpy as jnp

from repro.core import ActorSystem, In, NDRange, Out, dim_vec, kernel
from repro.kernels import ops

MX_DIM = 512


# Listing 1's kernel — the traceable callable is the "source"; ops.matmul
# dispatches to the Pallas MXU kernel on TPU. The @kernel declaration
# replaces the v1 positional spawn(source, name, nd_range, *specs).
@kernel(In(jnp.float32), In(jnp.float32),
        Out(jnp.float32, shape=(MX_DIM, MX_DIM)),
        nd_range=NDRange(dim_vec(MX_DIM, MX_DIM)), name="m_mult")
def m_mult(a, b):
    return ops.matmul(a, b)


def main() -> None:
    # Listing 2: create an actor system with the device module loaded
    with ActorSystem() as system:
        mngr = system.opencl_manager()
        print("platforms:", mngr.platforms)

        worker = system.spawn(m_mult)

        rng = np.random.default_rng(0)
        m1 = rng.random((MX_DIM, MX_DIM), np.float32)
        m2 = rng.random((MX_DIM, MX_DIM), np.float32)

        # request/receive (the paper's scoped_actor pattern)
        result = worker.ask(m1, m2)
        np.testing.assert_allclose(result, m1 @ m2, rtol=1e-4, atol=1e-4)
        print(f"m_mult ok: {MX_DIM}x{MX_DIM}, "
              f"|result|_F = {np.linalg.norm(result):.1f}")


if __name__ == "__main__":
    main()
