"""Batched greedy serving through a kernel actor: the decode step (one
token across a request batch, KV cache resident) is wrapped in an actor,
so requests flow in as messages and the cache never leaves the device —
the paper's resident-memory pipeline applied to LM decoding.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import Actor, ActorSystem
from repro.dist import step as step_mod
from repro.models import Model


class DecodeActor(Actor):
    """Owns params + KV cache; each message decodes one step for the batch."""

    def __init__(self, model: Model, params, batch: int, max_len: int):
        super().__init__()
        self.model = model
        self.params = params
        self.cache = model.init_cache(batch, max_len)
        self.step = jax.jit(step_mod.build_serve_step(model))

    def receive(self, tokens):
        nxt, logits, self.cache = self.step(self.params, self.cache,
                                            jnp.asarray(tokens))
        return np.asarray(nxt)


def main() -> None:
    cfg = configs.get_smoke_config("qwen3-1.7b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch, steps = 8, 32

    with ActorSystem() as system:
        decoder = system.spawn(DecodeActor(model, params, batch, steps + 1))
        toks = np.zeros((batch, 1), np.int32)
        outputs = [toks]
        t0 = time.perf_counter()
        for _ in range(steps):
            toks = decoder.ask(toks)
            outputs.append(toks)
        dt = time.perf_counter() - t0
        seqs = np.concatenate(outputs, axis=1)
        print(f"decoded {steps} steps × {batch} requests in {dt:.2f}s "
              f"({steps * batch / dt:.0f} tok/s)")
        print("first sequence:", seqs[0].tolist())


if __name__ == "__main__":
    main()
