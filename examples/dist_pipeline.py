"""Network-transparent two-process pipeline demo (paper §2.1/§3.5, ISSUE 5).

Spawns a worker **process**, connects it as a cluster node, and runs a
3-stage pipeline whose middle stage is a ``RemoteActorRef`` — the stage
boundary crosses the wire as exactly one int8-compressed spill/unspill
pair per hop (asserted on both processes' ``memory_stats()`` counters).
Then it SIGKILLs the worker mid-run to show cross-node supervision: local
monitors get a ``DownMessage`` and the dead node's in-flight chunks are
re-issued on the surviving local worker, every result exactly once.

The driver logic lives in ``repro.net.demo`` (module-level so the
``multiprocessing`` spawn child can import it); this file is the runnable
front door.

Run:  PYTHONPATH=src python examples/dist_pipeline.py
"""
import json

from repro.net import demo

if __name__ == "__main__":
    summary = demo.main()
    print(json.dumps(
        {k: (sorted(v) if isinstance(v, set) else v)
         for k, v in summary.items()}, indent=2, default=str))
    print("\nPASS: 3-stage cross-node pipeline, one spill/unspill pair per "
          "hop on each side, DownMessage + exactly-once re-issue after "
          "node death.")
