"""End-to-end training driver: train a small LM with the full substrate —
deterministic data pipeline, AdamW + warmup-cosine, checkpointing, and
actor-supervised recovery (a fault is injected mid-run and training
resumes from the last checkpoint, bit-exactly).

Defaults are CPU-sized; pass ``--arch`` and ``--steps`` to scale up
(e.g. ``--d-model 768 --layers 12`` ≈ a 100M-class model).

    PYTHONPATH=src python examples/train_lm.py --steps 100
"""
import argparse
import dataclasses
import tempfile
import time

import jax

from repro import configs
from repro.core import ActorSystem
from repro.data import SyntheticLM
from repro.dist import fault, step as step_mod
from repro.models import Model
from repro.optim import AdamWConfig, schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b",
                    choices=configs.list_archs())
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a worker fault at this step (demo)")
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    repl = {}
    if args.d_model:
        repl.update(d_model=args.d_model,
                    head_dim=args.d_model // max(cfg.n_heads, 1),
                    d_ff=args.d_model * 3)
    if args.layers:
        repl.update(n_layers=args.layers)
    if repl:
        cfg = dataclasses.replace(cfg, **repl)
    print(f"training {cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"~{cfg.param_count() / 1e6:.1f}M params")

    model = Model(cfg)
    ocfg = AdamWConfig(lr=3e-3, weight_decay=0.01)
    data = SyntheticLM(cfg, batch=args.batch, seq=args.seq, seed=0, noise=0.02)
    sched = schedule.warmup_cosine(args.steps // 10 + 1, args.steps)
    train_step = jax.jit(step_mod.build_train_step(model, ocfg,
                                                   lr_schedule=sched))
    state = step_mod.init_train_state(model, jax.random.key(0), ocfg)

    with tempfile.TemporaryDirectory() as ckpt_dir, ActorSystem() as system:
        trainer = fault.RecoverableTrainer(system, train_step, state, data,
                                           ckpt_dir, ckpt_every=10)
        t0 = time.perf_counter()
        fail_at = args.fail_at if args.fail_at is not None else args.steps // 2
        final = trainer.run(args.steps, fail_at=fail_at)
        dt = time.perf_counter() - t0
        # report the loss trajectory by re-evaluating a few checkpoints
        loss0 = float(model.loss(state["params"],
                                 {k: jax.numpy.asarray(v)
                                  for k, v in data.batch_at(0).items()})[0])
        lossN = float(model.loss(final["params"],
                                 {k: jax.numpy.asarray(v)
                                  for k, v in data.batch_at(0).items()})[0])
        tok_s = args.steps * args.batch * args.seq / dt
        print(f"steps={int(final['step'])} recoveries={trainer.recoveries} "
              f"(fault injected at step {fail_at})")
        print(f"loss: {loss0:.3f} → {lossN:.3f}  ({tok_s:,.0f} tok/s wall)")
        assert lossN < loss0, "training failed to reduce loss"
        print("OK: loss decreased; recovery transparent")


if __name__ == "__main__":
    main()
